//! The gradient oracle abstraction — what a "worker" computes.
//!
//! The coordinator is generic over this trait so the same EASGD /
//! DOWNPOUR / Tree drivers run against (a) the native models on
//! synthetic CIFAR-like data (figure sweeps, p up to 256) — the MLP
//! stand-in or the §4.1-faithful conv net, both behind the generic
//! [`NativeOracle`] — and (b) the AOT-lowered JAX transformer through
//! PJRT (`runtime::PjrtOracle`, the end-to-end example). Python is
//! never involved in either.

use crate::data::prefetch::{PrefetchPool, Sharding};
use crate::data::BlobDataset;
use crate::model::{BatchModel, ConvNet, ConvNetConfig, Mlp, MlpConfig};
use crate::rng::Rng;
use crate::sync::Arc;
use std::collections::VecDeque;

/// Evaluation summary for the center variable.
#[derive(Clone, Copy, Debug)]
pub struct EvalStats {
    pub train_loss: f64,
    pub test_loss: f64,
    pub test_error: f64,
}

/// A per-worker gradient computer. One oracle instance per worker
/// (holds its own scratch + data stream); implementations must be
/// deterministic given the worker's RNG stream. (No `Send` bound: the
/// PJRT oracle wraps raw PJRT pointers; the drivers are event-driven
/// single-thread by design — asynchrony lives in virtual time.)
pub trait GradOracle {
    fn n_params(&self) -> usize;
    /// Initial parameter vector (the SAME for master and all workers —
    /// thesis §4.1).
    fn init_params(&self) -> Vec<f32>;
    /// One mini-batch gradient at `theta` into `out`; returns the batch
    /// training loss.
    fn grad(&mut self, theta: &[f32], rng: &mut Rng, out: &mut [f32]) -> f32;
    /// Evaluate a parameter vector (test set + train probe).
    fn eval(&mut self, theta: &[f32]) -> EvalStats;
}

/// Boxed oracles are oracles: the process backend rebuilds workers
/// from a serialized [`super::process::OracleSpec`], whose `build`
/// necessarily returns `Box<dyn GradOracle + Send>`.
impl<O: GradOracle + ?Sized> GradOracle for Box<O> {
    fn n_params(&self) -> usize {
        (**self).n_params()
    }

    fn init_params(&self) -> Vec<f32> {
        (**self).init_params()
    }

    fn grad(&mut self, theta: &[f32], rng: &mut Rng, out: &mut [f32]) -> f32 {
        (**self).grad(theta, rng, out)
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        (**self).eval(theta)
    }
}

/// Native oracle over the blob dataset, generic over the
/// [`BatchModel`] (MLP or conv net), fed through the §4.1 prefetch
/// pipeline. Whole mini-batches flow through the model's batch-major
/// GEMM path (`grad_batch` / `eval_batch`); the scratch panels inside
/// the model are reused so the steady-state `grad` call is
/// allocation-free on the model side.
pub struct NativeOracle<M: BatchModel> {
    data: Arc<BlobDataset>,
    model: M,
    pool: PrefetchPool,
    /// Mini-batches cut by the pool, served FRONT-first so workers
    /// consume them in the order the shuffled union was cut (the seed
    /// `pop()`ed the back, reversing every fetch).
    queue: VecDeque<Vec<usize>>,
    init_seed: u64,
    /// Fixed probe subset for train loss (cheap, low-variance).
    probe: Vec<usize>,
}

/// The historical sweep oracle: [`NativeOracle`] over the MLP stand-in.
pub type MlpOracle = NativeOracle<Mlp>;

/// The §4.1-faithful conv oracle (`model=conv`): [`NativeOracle`] over
/// the im2col + GEMM conv net, the blob input read as a 1×h×w image.
pub type ConvOracle = NativeOracle<ConvNet>;

impl<M: BatchModel> NativeOracle<M> {
    /// Wrap an explicit model instance with an explicit §4.1 prefetch
    /// sharding mode: every loader owns the whole dataset
    /// (`Replicated`, CIFAR mode) or a distinct 1/k shard
    /// (`Partitioned`, ImageNet mode).
    pub fn with_model(
        data: Arc<BlobDataset>,
        model: M,
        batch: usize,
        seed: u64,
        sharding: Sharding,
    ) -> Self {
        assert_eq!(model.in_dim(), data.dim, "model input dim vs dataset dim");
        assert_eq!(model.n_classes(), data.classes, "model classes vs dataset classes");
        let pool = PrefetchPool::new(data.train.len(), 4, batch * 2, batch, sharding, seed);
        let probe = (0..256.min(data.train.len())).collect();
        Self {
            data,
            model,
            pool,
            queue: VecDeque::new(),
            init_seed: 9000,
            probe,
        }
    }

    /// Next mini-batch of sample indices, ALWAYS from the §4.1 prefetch
    /// pipeline: keep fetching until the pool cuts at least one full
    /// mini-batch (early fetches can come back empty while the
    /// shuffled union is still smaller than `batch` — the carry
    /// accumulates, so this loop terminates), and serve the cuts in
    /// order. The seed silently fell back to uniform i.i.d. indices on
    /// an empty fetch, bypassing the chunked loaders/sharding/carry
    /// semantics the Replicated-vs-Partitioned comparisons depend on.
    fn next_batch(&mut self, rng: &mut Rng) -> Vec<usize> {
        loop {
            if let Some(mb) = self.queue.pop_front() {
                return mb;
            }
            self.queue.extend(self.pool.fetch_minibatches(rng));
        }
    }
}

impl NativeOracle<Mlp> {
    /// Replicated-loader oracle (the §4.1 CIFAR mode, the sweep
    /// default). Use [`MlpOracle::new_sharded`] to pick the mode.
    pub fn new(data: Arc<BlobDataset>, cfg: MlpConfig, batch: usize, seed: u64) -> Self {
        Self::new_sharded(data, cfg, batch, seed, Sharding::Replicated)
    }

    /// MLP oracle with an explicit §4.1 prefetch sharding mode.
    pub fn new_sharded(
        data: Arc<BlobDataset>,
        cfg: MlpConfig,
        batch: usize,
        seed: u64,
        sharding: Sharding,
    ) -> Self {
        Self::with_model(data, Mlp::new(cfg), batch, seed, sharding)
    }

    /// Sweep-default oracle family: every worker shares the dataset
    /// through replicated loaders, distinct RNG streams.
    pub fn family(data: Arc<BlobDataset>, cfg: &MlpConfig, batch: usize, p: usize) -> Vec<Self> {
        Self::family_sharded(data, cfg, batch, p, Sharding::Replicated)
    }

    /// Oracle family with an explicit prefetch sharding mode (the
    /// `sharding=` knob of the `train` CLI and the ch4 sweeps).
    pub fn family_sharded(
        data: Arc<BlobDataset>,
        cfg: &MlpConfig,
        batch: usize,
        p: usize,
        sharding: Sharding,
    ) -> Vec<Self> {
        (0..p)
            .map(|i| {
                Self::new_sharded(data.clone(), cfg.clone(), batch, 40_000 + i as u64, sharding)
            })
            .collect()
    }
}

impl NativeOracle<ConvNet> {
    /// Conv oracle with an explicit §4.1 prefetch sharding mode.
    pub fn new_sharded(
        data: Arc<BlobDataset>,
        cfg: ConvNetConfig,
        batch: usize,
        seed: u64,
        sharding: Sharding,
    ) -> Self {
        Self::with_model(data, ConvNet::new(cfg), batch, seed, sharding)
    }

    /// Conv oracle family (the `model=conv` sweeps), same seed layout
    /// as [`MlpOracle::family_sharded`] so curves are comparable.
    pub fn family_sharded(
        data: Arc<BlobDataset>,
        cfg: &ConvNetConfig,
        batch: usize,
        p: usize,
        sharding: Sharding,
    ) -> Vec<Self> {
        (0..p)
            .map(|i| {
                Self::new_sharded(data.clone(), cfg.clone(), batch, 40_000 + i as u64, sharding)
            })
            .collect()
    }
}

impl<M: BatchModel> GradOracle for NativeOracle<M> {
    fn n_params(&self) -> usize {
        self.model.n_params()
    }

    fn init_params(&self) -> Vec<f32> {
        let mut rng = Rng::new(self.init_seed);
        self.model.init_params(&mut rng)
    }

    fn grad(&mut self, theta: &[f32], rng: &mut Rng, out: &mut [f32]) -> f32 {
        // The whole mini-batch goes through the GEMM path in one
        // forward/backward; `grad_batch` writes the mean gradient and
        // returns the mean loss (incl. l2), exactly the per-sample
        // loop's semantics.
        let idx = self.next_batch(rng);
        let data = &self.data;
        self.model.grad_batch(
            theta,
            idx.iter().map(|&i| {
                let (x, y) = &data.train[i];
                (x.as_slice(), *y)
            }),
            out,
        )
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        // Batched eval in fixed 128-row panels; the O(n_params) l2
        // scan runs ONCE per θ and is shared across every sample (the
        // seed recomputed it inside each `loss` call). The panel size
        // is deliberately NOT scaled with the `threads=` knob: the
        // GEMMs inside a 128-row panel already clear the pool's
        // work threshold, so they split across the hybrid helpers
        // (bitwise-identically), while the fixed panel keeps the f64
        // nll accumulation grouping — and hence every reported loss —
        // byte-for-byte independent of the thread count.
        let panel = 128;
        let l2 = self.model.l2_penalty(theta) as f64;
        let data = &self.data;
        let mut train_nll = 0.0f64;
        for chunk in self.probe.chunks(panel) {
            let (nll, _) = self.model.eval_batch(
                theta,
                chunk.iter().map(|&i| {
                    let (x, y) = &data.train[i];
                    (x.as_slice(), *y)
                }),
            );
            train_nll += nll;
        }
        let mut test_nll = 0.0f64;
        let mut wrong = 0usize;
        for chunk in data.test.chunks(panel) {
            let (nll, w) = self
                .model
                .eval_batch(theta, chunk.iter().map(|(x, y)| (x.as_slice(), *y)));
            test_nll += nll;
            wrong += w;
        }
        // Guarded divisions: an empty probe/test set means 0 samples,
        // so the stat is DEFINED as 0 rather than the 0/0 = NaN the
        // seed emitted (a NaN here poisons every figure CSV
        // downstream). No debug assert on emptiness — the guarded path
        // is itself under test.
        let train_loss = if self.probe.is_empty() {
            0.0
        } else {
            train_nll / self.probe.len() as f64 + l2
        };
        let (test_loss, test_error) = if data.test.is_empty() {
            (0.0, 0.0)
        } else {
            (
                test_nll / data.test.len() as f64 + l2,
                wrong as f64 / data.test.len() as f64,
            )
        };
        EvalStats { train_loss, test_loss, test_error }
    }
}

/// Deterministic quadratic oracle: f(θ) = mean_i ½·h·(θ_i − b)², with
/// optional per-coordinate gradient noise g_i = h(θ_i − b) − σ·ξ_i
/// (the §3.1.1 additive-noise model lifted to n dimensions). Used by
/// the executor-equivalence tests (both backends must reach the same
/// loss on it) and the thread-scaling bench, where the gradient cost
/// must be trivial and tunable via n.
pub struct QuadraticOracle {
    n: usize,
    h: f32,
    x0: f32,
    target: f32,
    noise: f32,
}

impl QuadraticOracle {
    pub fn new(n: usize, h: f32, x0: f32, target: f32, noise: f32) -> Self {
        assert!(n > 0 && h > 0.0);
        Self { n, h, x0, target, noise }
    }

    /// p identical oracles (workers share the objective; their noise
    /// streams come from the driver's per-worker RNGs).
    pub fn family(n: usize, h: f32, x0: f32, target: f32, noise: f32, p: usize) -> Vec<Self> {
        (0..p).map(|_| Self::new(n, h, x0, target, noise)).collect()
    }

    fn loss_of(&self, theta: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for &t in theta {
            let d = (t - self.target) as f64;
            acc += 0.5 * self.h as f64 * d * d;
        }
        acc / self.n as f64
    }
}

impl GradOracle for QuadraticOracle {
    fn n_params(&self) -> usize {
        self.n
    }

    fn init_params(&self) -> Vec<f32> {
        vec![self.x0; self.n]
    }

    fn grad(&mut self, theta: &[f32], rng: &mut Rng, out: &mut [f32]) -> f32 {
        for (o, &t) in out.iter_mut().zip(theta) {
            let mut g = self.h * (t - self.target);
            if self.noise > 0.0 {
                g -= self.noise * rng.gaussian() as f32;
            }
            *o = g;
        }
        self.loss_of(theta) as f32
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        let loss = self.loss_of(theta);
        EvalStats {
            train_loss: loss,
            test_loss: loss,
            test_error: loss.min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_setup() -> (Arc<BlobDataset>, MlpConfig) {
        let data = Arc::new(BlobDataset::generate(8, 4, 512, 128, 0.8, 1));
        let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        (data, cfg)
    }

    #[test]
    fn oracle_gradient_descends() {
        let (data, cfg) = small_setup();
        let mut o = MlpOracle::new(data, cfg, 32, 7);
        let mut theta = o.init_params();
        let mut g = vec![0.0; o.n_params()];
        let mut rng = Rng::new(1);
        let e0 = o.eval(&theta);
        for _ in 0..150 {
            o.grad(&theta, &mut rng, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.2);
        }
        let e1 = o.eval(&theta);
        assert!(e1.train_loss < e0.train_loss - 0.2, "{:?} -> {:?}", e0, e1);
        assert!(e1.test_error < e0.test_error, "{:?} -> {:?}", e0, e1);
    }

    #[test]
    fn conv_oracle_gradient_descends() {
        // The conv stand-in trains end-to-end through the same oracle
        // machinery: blob input read as a 1×2×4 image.
        let (data, _) = small_setup();
        let cfg = ConvNetConfig::for_blob(8, 4, 1e-4);
        let mut o = ConvOracle::new_sharded(data, cfg, 32, 7, Sharding::Replicated);
        let mut theta = o.init_params();
        let mut g = vec![0.0; o.n_params()];
        let mut rng = Rng::new(1);
        let e0 = o.eval(&theta);
        for _ in 0..150 {
            o.grad(&theta, &mut rng, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.1);
        }
        let e1 = o.eval(&theta);
        assert!(e1.train_loss < e0.train_loss, "{:?} -> {:?}", e0, e1);
        // Weight sharing constrains the tiny conv net, so only require
        // that generalization does not regress materially.
        assert!(e1.test_error <= e0.test_error + 0.05, "{:?} -> {:?}", e0, e1);
    }

    #[test]
    fn init_params_identical_across_family() {
        let (data, cfg) = small_setup();
        let fam = MlpOracle::family(data, &cfg, 32, 4);
        let base = fam[0].init_params();
        for o in &fam[1..] {
            assert_eq!(o.init_params(), base, "shared init (§4.1)");
        }
    }

    #[test]
    fn conv_family_shares_init_and_matches_mlp_contract() {
        let (data, _) = small_setup();
        let cfg = ConvNetConfig::for_blob(8, 4, 1e-4);
        let fam = ConvOracle::family_sharded(data, &cfg, 32, 3, Sharding::Replicated);
        let base = fam[0].init_params();
        assert_eq!(base.len(), fam[0].n_params());
        for o in &fam[1..] {
            assert_eq!(o.init_params(), base, "shared init (§4.1)");
        }
    }

    #[test]
    fn partitioned_family_trains_like_replicated() {
        // The §4.1 ImageNet mode: each of a worker's 4 loaders owns a
        // distinct quarter of the training set. Gradients still
        // descend — the union of the shards is the whole set.
        let (data, cfg) = small_setup();
        let fam = MlpOracle::family_sharded(data, &cfg, 32, 2, Sharding::Partitioned);
        let mut o = fam.into_iter().next().unwrap();
        let mut theta = o.init_params();
        let mut g = vec![0.0; o.n_params()];
        let mut rng = Rng::new(2);
        let e0 = o.eval(&theta);
        for _ in 0..150 {
            o.grad(&theta, &mut rng, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.2);
        }
        let e1 = o.eval(&theta);
        assert!(e1.train_loss < e0.train_loss - 0.2, "{:?} -> {:?}", e0, e1);
    }

    /// Regression for the silent uniform-sampling fallback: every index
    /// the oracle serves must have flowed through the prefetch pool,
    /// in the exact order the pool cut its mini-batches. A shadow pool
    /// built with the oracle's constructor parameters and driven by an
    /// identical RNG stream must predict every served batch; the old
    /// fallback (fresh `rng.below` draws) and the old reversed `pop()`
    /// order both diverge from this prediction immediately.
    #[test]
    fn served_batches_flow_through_the_pool_in_cut_order() {
        let (data, cfg) = small_setup();
        let batch = 32;
        let seed = 77;
        for sharding in [Sharding::Replicated, Sharding::Partitioned] {
            let mut o = MlpOracle::new_sharded(data.clone(), cfg.clone(), batch, seed, sharding);
            let mut shadow =
                PrefetchPool::new(data.train.len(), 4, batch * 2, batch, sharding, seed);
            let mut rng_o = Rng::new(5);
            let mut rng_s = Rng::new(5);
            let mut expected: VecDeque<Vec<usize>> = VecDeque::new();
            for step in 0..40 {
                let got = o.next_batch(&mut rng_o);
                while expected.is_empty() {
                    expected.extend(shadow.fetch_minibatches(&mut rng_s));
                }
                let want = expected.pop_front().unwrap();
                assert_eq!(got, want, "{sharding:?} step {step}: not the pool's cut order");
            }
        }
    }

    /// A tiny dataset under `Partitioned` sharding: every loader owns a
    /// 4-sample shard it must cycle repeatedly per fetch — the oracle
    /// must serve only pool-fetched indices, never fall back to
    /// uniform sampling, and never panic on the small shards.
    #[test]
    fn next_batch_survives_small_fetches_without_fallback() {
        let data = Arc::new(BlobDataset::generate(8, 4, 16, 8, 0.8, 3));
        let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut o =
            MlpOracle::new_sharded(data.clone(), cfg, 8, 11, Sharding::Partitioned);
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let mb = o.next_batch(&mut rng);
            assert_eq!(mb.len(), 8);
            assert!(mb.iter().all(|&i| i < data.train.len()));
        }
    }

    #[test]
    fn eval_stats_are_deterministic_for_same_theta() {
        let (data, cfg) = small_setup();
        let mut o = MlpOracle::new(data, cfg, 32, 7);
        let theta = o.init_params();
        let a = o.eval(&theta);
        let b = o.eval(&theta);
        assert_eq!(a.train_loss, b.train_loss);
        assert_eq!(a.test_error, b.test_error);
    }

    /// Regression for the unguarded `/ data.test.len()`: an empty test
    /// set used to yield NaN test stats that poisoned every figure CSV
    /// downstream; they are now defined as 0.
    #[test]
    fn eval_with_empty_test_set_yields_zero_not_nan() {
        let data = Arc::new(BlobDataset::generate(8, 4, 64, 0, 0.8, 1));
        assert!(data.test.is_empty());
        let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut o = MlpOracle::new(data, cfg, 16, 7);
        let theta = o.init_params();
        let e = o.eval(&theta);
        assert!(e.train_loss.is_finite());
        assert_eq!(e.test_loss, 0.0, "empty test set defines test_loss = 0");
        assert_eq!(e.test_error, 0.0, "empty test set defines test_error = 0");
    }

    #[test]
    fn quadratic_oracle_gradient_descends_to_target() {
        let mut o = QuadraticOracle::new(32, 2.0, 0.0, 1.0, 0.0);
        let mut theta = o.init_params();
        let mut g = vec![0.0; 32];
        let mut rng = Rng::new(1);
        let l0 = o.eval(&theta).train_loss;
        assert!((l0 - 1.0).abs() < 1e-6, "½·2·1² = 1, got {l0}");
        for _ in 0..200 {
            o.grad(&theta, &mut rng, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.2);
        }
        let l1 = o.eval(&theta).train_loss;
        assert!(l1 < 1e-10, "loss {l1}");
        assert!(theta.iter().all(|t| (t - 1.0).abs() < 1e-4));
    }

    #[test]
    fn quadratic_oracle_noise_uses_worker_stream() {
        let mut o = QuadraticOracle::new(8, 1.0, 0.0, 0.0, 0.5);
        let theta = vec![0.0f32; 8];
        let mut g1 = vec![0.0f32; 8];
        let mut g2 = vec![0.0f32; 8];
        o.grad(&theta, &mut Rng::new(3), &mut g1);
        o.grad(&theta, &mut Rng::new(3), &mut g2);
        assert_eq!(g1, g2, "same stream ⇒ same noise");
        o.grad(&theta, &mut Rng::new(4), &mut g2);
        assert_ne!(g1, g2, "different stream ⇒ different noise");
    }
}
