//! Hand-rolled repo-invariant lint: a tier-1 `#[test]` (no new
//! dependencies, plain `std::fs`) that walks `rust/src` and enforces
//! the concurrency-correctness conventions the `crate::sync` shim and
//! the loom/Miri/TSan lanes rely on:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 | no `std::sync` / `std::thread` outside `sync/mod.rs` — all concurrent code imports through the shim, so `--cfg loom` instruments every lock, notify, and spawn |
//! | R2 | no `unsafe` outside the committed allowlist (`linalg/gemm.rs`, whose Job aliasing invariants are documented at the type, and `linalg/simd.rs`, the intrinsic kernel tier) |
//! | R3 | any file using `catch_unwind` also uses `lock_recover` — catching a panic without recovering poisoned locks deadlocks the survivors |
//! | R4 | `.unwrap()` / `.expect(` in `coordinator/*` non-test code stays at or below the committed per-file ceiling — the count can only shrink |
//!
//! Scope: non-test code only. Each source file's `#[cfg(test)] mod`
//! sits at the bottom (repo convention), so the lint truncates the
//! stripped source at the first `#[cfg(test)]`. Comments and string
//! literals are stripped first, so prose mentioning `std::thread` or
//! an error message quoting `unsafe` never trips a rule. The vendored
//! crates (`rust/vendor/*`) are outside `src/` and deliberately exempt
//! (the loom stub IS an instrumented `std::sync`).

use std::fs;
use std::path::{Path, PathBuf};

/// Files allowed to name `std::sync` / `std::thread` directly: the
/// shim itself (its whole job is re-exporting them).
const SYNC_IMPORT_ALLOWLIST: &[&str] = &["sync/mod.rs"];

/// The entire committed `unsafe` surface, per file. Growing a count
/// here must come with the same scrutiny as `gemm.rs`'s Job aliasing
/// invariants; everything not listed is `unsafe`-free.
const UNSAFE_ALLOWLIST: &[(&str, usize)] = &[
    // 1 `unsafe impl Send for Job` + 3 slice reconstructions in
    // `exec_span` + the `COut::row` &mut materialization, each
    // annotated with the invariant it leans on.
    ("linalg/gemm.rs", 5),
    // 8 dispatch-wrapper call sites (4 kernels × {avx2, neon}) + 8 AVX2
    // + 7 NEON `#[target_feature]` kernel fns; see the module doc for
    // why each is sound. All cfg-gated behind `--features simd`, but
    // the lint is textual so they count unconditionally.
    ("linalg/simd.rs", 23),
];

/// Per-file ceilings on `.unwrap()` + `.expect(` in non-test
/// `coordinator/*` code. Every remaining site is a documented
/// structural invariant (e.g. "averaged methods allocate z at init")
/// or an infallible conversion (wire.rs's fixed-width `try_into`s);
/// anything fallible returns a typed `crate::error::Error` instead.
/// Lower a ceiling when you remove a site; never raise one without a
/// matching invariant comment at the call site.
const UNWRAP_CEILINGS: &[(&str, usize)] = &[
    ("coordinator/driver.rs", 5),
    ("coordinator/master_actor.rs", 3),
    ("coordinator/process.rs", 1),
    ("coordinator/threaded.rs", 2),
    ("coordinator/topology.rs", 3),
    ("coordinator/tree_threaded.rs", 1),
    ("coordinator/wire.rs", 6),
];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {dir:?}: {e}"));
    for entry in entries {
        let path = entry.expect("readable directory entry").path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
}

/// Strip comments and string literals (newlines preserved so reported
/// line numbers stay true), then truncate at the first `#[cfg(test)]`
/// — the bottom-of-file tests module, per repo convention.
fn lintable_source(raw: &str) -> String {
    let bytes = raw.as_bytes();
    let mut out = String::with_capacity(raw.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if bytes[i] == b'\n' {
                            out.push('\n');
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            out.push('\n');
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
            }
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    if let Some(pos) = out.find("#[cfg(test)]") {
        out.truncate(pos);
    }
    out
}

/// Load every `src/**/*.rs` as `(path relative to src/, stripped
/// non-test source)`.
fn sources() -> Vec<(String, String)> {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();
    assert!(files.len() >= 20, "walked only {} files — wrong root?", files.len());
    files
        .into_iter()
        .map(|p| {
            let rel = p
                .strip_prefix(&src)
                .expect("collected under src/")
                .to_string_lossy()
                .replace('\\', "/");
            let raw = fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {p:?}: {e}"));
            (rel, lintable_source(&raw))
        })
        .collect()
}

/// 1-based line number of byte offset `pos`.
fn line_of(text: &str, pos: usize) -> usize {
    text[..pos].bytes().filter(|&b| b == b'\n').count() + 1
}

/// Occurrences of `needle` with identifier boundaries on both sides
/// (so `unsafe` never matches inside a longer word).
fn count_word(text: &str, needle: &str) -> usize {
    let is_ident = |b: u8| b == b'_' || b.is_ascii_alphanumeric();
    let mut n = 0;
    let mut from = 0;
    while let Some(off) = text[from..].find(needle) {
        let start = from + off;
        let end = start + needle.len();
        let left_ok = start == 0 || !is_ident(text.as_bytes()[start - 1]);
        let right_ok = end >= text.len() || !is_ident(text.as_bytes()[end]);
        if left_ok && right_ok {
            n += 1;
        }
        from = start + 1;
    }
    n
}

fn count_substr(text: &str, needle: &str) -> usize {
    let mut n = 0;
    let mut from = 0;
    while let Some(off) = text[from..].find(needle) {
        n += 1;
        from += off + 1;
    }
    n
}

#[test]
fn r1_no_std_sync_or_thread_outside_the_shim() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if SYNC_IMPORT_ALLOWLIST.contains(&rel.as_str()) {
            continue;
        }
        for needle in ["std::sync", "std::thread"] {
            let mut from = 0;
            while let Some(off) = text[from..].find(needle) {
                let pos = from + off;
                violations.push(format!(
                    "{rel}:{}: `{needle}` outside sync/mod.rs — import through \
                     `crate::sync` so `--cfg loom` instruments it",
                    line_of(&text, pos)
                ));
                from = pos + 1;
            }
        }
    }
    assert!(violations.is_empty(), "R1 violations:\n{}", violations.join("\n"));
}

#[test]
fn r2_unsafe_stays_inside_the_allowlist() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        let n = count_word(&text, "unsafe");
        let cap = UNSAFE_ALLOWLIST
            .iter()
            .find(|(f, _)| *f == rel)
            .map_or(0, |(_, c)| *c);
        if n > cap {
            violations.push(format!(
                "{rel}: {n} `unsafe` occurrence(s), allowlist permits {cap} — document \
                 the aliasing invariants and extend UNSAFE_ALLOWLIST deliberately"
            ));
        }
    }
    assert!(violations.is_empty(), "R2 violations:\n{}", violations.join("\n"));
}

#[test]
fn r3_catch_unwind_is_paired_with_lock_recover() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if text.contains("catch_unwind") && !text.contains("lock_recover") {
            violations.push(format!(
                "{rel}: uses `catch_unwind` without `lock_recover` — a caught panic \
                 leaves poisoned locks that every surviving thread must recover"
            ));
        }
    }
    assert!(violations.is_empty(), "R3 violations:\n{}", violations.join("\n"));
}

#[test]
fn r4_coordinator_unwrap_count_only_shrinks() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if !rel.starts_with("coordinator/") {
            continue;
        }
        let n = count_substr(&text, ".unwrap()") + count_substr(&text, ".expect(");
        let cap = UNWRAP_CEILINGS
            .iter()
            .find(|(f, _)| *f == rel)
            .map_or(0, |(_, c)| *c);
        if n > cap {
            violations.push(format!(
                "{rel}: {n} `.unwrap()`/`.expect(` site(s) in non-test code, ceiling is \
                 {cap} — return a typed `crate::error::Error` instead (or, for a true \
                 structural invariant, document it at the call site and raise the \
                 ceiling in the same change)"
            ));
        }
    }
    assert!(violations.is_empty(), "R4 violations:\n{}", violations.join("\n"));
}

/// The ceilings themselves must stay honest: a stale entry (file
/// removed or renamed) would silently allowlist a future file of the
/// same name.
#[test]
fn lint_tables_reference_existing_files() {
    let files: Vec<String> = sources().into_iter().map(|(rel, _)| rel).collect();
    for (f, _) in UNSAFE_ALLOWLIST.iter().chain(UNWRAP_CEILINGS) {
        assert!(files.iter().any(|r| r == f), "lint table references missing file {f}");
    }
    for f in SYNC_IMPORT_ALLOWLIST {
        assert!(files.iter().any(|r| r == f), "lint table references missing file {f}");
    }
}
