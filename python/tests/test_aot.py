"""AOT pipeline integrity: exported artifacts parse, manifest is
consistent with the model's param table, HLO entry signatures match."""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import model as M
from compile import aot

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_param_table_matches_model(manifest):
    cfg = M.PRESETS[manifest["preset"]]
    specs = M.param_specs(cfg)
    assert len(manifest["params"]) == len(specs)
    off = 0
    for entry, (name, shape) in zip(manifest["params"], specs):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == tuple(shape)
        assert entry["offset"] == off
        off += entry["size"]
    assert off == manifest["preset_params"]


def test_init_params_bin_matches_manifest(manifest):
    flat = np.fromfile(os.path.join(ART, "init_params.bin"),
                       dtype=np.float32)
    assert flat.size == manifest["preset_params"]
    assert np.all(np.isfinite(flat))
    # scales init to exactly 1.0 — spot-check the first ln scale slice.
    entry = next(e for e in manifest["params"]
                 if e["name"].endswith("ln1_scale"))
    sl = flat[entry["offset"]: entry["offset"] + entry["size"]]
    np.testing.assert_array_equal(sl, np.ones_like(sl))


@pytest.mark.parametrize("key", ["train_step", "eval_step", "sgd_step",
                                 "elastic", "fused_step"])
def test_hlo_artifacts_exist_and_are_hlo_text(manifest, key):
    path = os.path.join(ART, manifest["artifacts"][key])
    with open(path) as f:
        text = f.read()
    assert "HloModule" in text
    assert "ENTRY" in text
    # Entry computation must declare the expected number of parameters.
    n_params = text.count("parameter(")
    expected = {
        "train_step": len(manifest["params"]) + 2,
        "eval_step": len(manifest["params"]) + 2,
        "sgd_step": 5,
        "elastic": 3,
        "fused_step": 8,
    }[key]
    assert n_params >= expected


def test_hlo_is_text_not_proto(manifest):
    """Guard against regressing to .serialize() (64-bit-id protos that
    xla_extension 0.5.1 rejects)."""
    path = os.path.join(ART, manifest["artifacts"]["train_step"])
    with open(path, "rb") as f:
        head = f.read(64)
    assert head.decode("utf-8", errors="strict").startswith("HloModule")


def test_export_roundtrip_small_preset(tmp_path):
    """Full export into a temp dir with a throwaway config — exercises
    aot.py end to end without touching the repo artifacts."""
    cfg = M.ModelConfig(vocab=32, d_model=32, n_layers=1, n_heads=2,
                        seq_len=32, batch=2)
    man = {"preset": "test"}
    man.update(aot.export_model(cfg, str(tmp_path), seed=9))
    man["kernels"] = aot.export_update_kernels(man["preset_params"],
                                               str(tmp_path))
    assert (tmp_path / "train_step.hlo.txt").exists()
    flat = np.fromfile(tmp_path / "init_params.bin", dtype=np.float32)
    assert flat.size == man["preset_params"]
    assert man["kernels"]["flat_len"] == man["preset_params"]
