//! `fuzz_wire` — deterministic, dependency-free fuzzer for the wire
//! decoder ([`elastic_train::coordinator::wire::recv_frame`]) and the
//! protocol conformance checker
//! ([`elastic_train::coordinator::protocol::ProtocolState`]).
//!
//! The contract under fuzz: hostile bytes and hostile frame orderings
//! must ALWAYS produce typed `crate::error::Error`s — never a panic,
//! and never an allocation sized by an attacker-controlled length
//! prefix. A counting global allocator enforces the latter on every
//! iteration; a panic hook names the failing iteration and seed so any
//! crash is reproducible with `iters=1 seed=<s> skip=<i>`-style
//! bisection (the whole run is a pure function of `seed=`).
//!
//! Mutation classes (picked per iteration from the split RNG):
//! valid-roundtrip, header bit flips, payload bit flips, truncation,
//! length-field lies, kind/version/magic swaps, max-`n` claims, and
//! random protocol walks on both side's state machines.
//!
//! The max-`n` class is also CI's mutation-teeth probe: claims above
//! `MAX_PAYLOAD` must be rejected BY THE CAP (an error naming the
//! cap), not merely by running out of bytes. A build with the guard
//! compiled out (`--cfg wire_mutate_no_payload_cap`) still returns
//! typed errors — but the wrong class — so this fuzzer exits nonzero,
//! which the CI `fuzz` lane REQUIRES for that build.
//!
//! Usage: `fuzz_wire [iters=100000] [seed=1] [--quick] [corpus=DIR]`
//! (`--quick` caps iterations at 20k for pre-merge lanes; the corpus
//! under `tests/corpus/wire/` is replayed before the random phase).

use elastic_train::config::Args;
use elastic_train::coordinator::protocol::{Dir, ProtoState, ProtocolState, TRANSITIONS};
use elastic_train::coordinator::wire::{
    recv_frame, send_frame, Frame, FrameKind, WireClock, HEADER_BYTES, MAGIC, MAX_PAYLOAD,
    READ_CHUNK_BYTES, VERSION,
};
use elastic_train::rng::Rng;
use elastic_train::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::alloc::{GlobalAlloc, Layout, System};

/// Counting allocator: tracks current and peak live bytes so each
/// iteration can assert its allocation stayed bounded regardless of
/// what the length prefix claimed.
struct CountingAlloc;

static CUR: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

// SAFETY: defers entirely to `System`; the bookkeeping uses only
// atomics and cannot affect the returned pointers.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let cur = CUR.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(cur, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        CUR.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Generous per-iteration allocation budget: base frames stay under
/// 4096 f32s, so a decode may hold the mutated buffer + one read
/// chunk + the payload with room to spare — while a length-prefix
/// sized allocation (up to 1 GiB under the cap, 16 GiB at u32::MAX)
/// blows straight through it.
const ALLOC_BUDGET: usize = READ_CHUNK_BYTES + (1 << 20);

static ITER: AtomicU64 = AtomicU64::new(0);

fn main() {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 1).unwrap_or(1);
    let mut iters = args.get_u64("iters", 100_000).unwrap_or(100_000);
    if args.get("quick").is_some() {
        iters = iters.min(20_000);
    }
    let default_corpus =
        format!("{}/tests/corpus/wire", env!("CARGO_MANIFEST_DIR"));
    let corpus = args.get_str("corpus", &default_corpus).to_string();

    // Any panic below is a fuzzing FAILURE; name the spot so the run
    // is reproducible before the process dies with a nonzero status.
    std::panic::set_hook(Box::new(|info| {
        eprintln!(
            "fuzz_wire: PANIC at iteration {} — reproduce with seed= of this run\n{info}",
            ITER.load(Ordering::Relaxed)
        );
    }));

    let mut failures: u64 = 0;
    let mut report = |what: String, failures: &mut u64| {
        *failures += 1;
        if *failures <= 10 {
            eprintln!("fuzz_wire: FAIL: {what}");
        }
    };

    // Phase 1: committed regression corpus.
    let mut corpus_files = 0usize;
    match std::fs::read_dir(&corpus) {
        Err(e) => report(format!("cannot read corpus dir {corpus}: {e}"), &mut failures),
        Ok(dir) => {
            let mut paths: Vec<_> = dir.filter_map(|e| e.ok().map(|e| e.path())).collect();
            paths.sort();
            for path in paths {
                if path.extension() != Some(std::ffi::OsStr::new("bin")) {
                    continue;
                }
                corpus_files += 1;
                let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
                let bytes = match std::fs::read(&path) {
                    Ok(b) => b,
                    Err(e) => {
                        report(format!("cannot read corpus file {name}: {e}"), &mut failures);
                        continue;
                    }
                };
                match replay(&bytes) {
                    Ok(frames) if name.starts_with("err_") => report(
                        format!("{name}: expected a typed error, decoded {frames} frames cleanly"),
                        &mut failures,
                    ),
                    Err(e) if name.starts_with("ok_") => {
                        report(format!("{name}: expected a clean parse, got: {e}"), &mut failures)
                    }
                    _ => {}
                }
            }
            if corpus_files < 10 {
                report(
                    format!("corpus dir {corpus} has only {corpus_files} .bin files — moved?"),
                    &mut failures,
                );
            }
        }
    }

    // Phase 2: deterministic random mutations.
    let mut root = Rng::new(seed);
    let mut rng = root.split(0xF0);
    for i in 0..iters {
        ITER.store(i, Ordering::Relaxed);
        let base = base_frame(&mut rng);
        let mut buf = Vec::new();
        let mut ck = WireClock::default();
        if let Err(e) = send_frame(&mut buf, &base, &mut ck) {
            report(format!("iter {i}: send of a valid frame failed: {e}"), &mut failures);
            continue;
        }
        let before = CUR.load(Ordering::Relaxed);
        PEAK.store(before, Ordering::Relaxed);
        if let Some(what) = mutate_and_check(&mut rng, &base, buf) {
            report(format!("iter {i}: {what}"), &mut failures);
        }
        let peak_delta = PEAK.load(Ordering::Relaxed).saturating_sub(before);
        if peak_delta > ALLOC_BUDGET {
            report(
                format!(
                    "iter {i}: decode allocated {peak_delta} bytes (budget {ALLOC_BUDGET}) — \
                     a length prefix is being trusted before bytes arrive"
                ),
                &mut failures,
            );
        }
    }

    println!(
        "fuzz_wire: {iters} mutations + {corpus_files} corpus files, seed {seed}: {}",
        if failures == 0 { "OK".to_string() } else { format!("{failures} FAILURES") }
    );
    if failures > 0 {
        std::process::exit(1);
    }
}

/// A plausible in-protocol frame with a random kind / wid / clock and
/// a payload of up to 4096 f32s.
fn base_frame(rng: &mut Rng) -> Frame {
    let kind = FrameKind::ALL[rng.below(FrameKind::ALL.len())];
    let n = match rng.below(4) {
        0 => 0,
        1 => rng.below(8),
        2 => rng.below(256),
        _ => rng.below(4096),
    };
    let mut payload = vec![0f32; n];
    for x in payload.iter_mut() {
        *x = f32::from_bits(rng.next_u64() as u32);
    }
    Frame::new(kind, rng.next_u64() as u32, rng.next_u64(), payload)
}

/// Decode a full byte stream frame-by-frame, driving the master-side
/// checker (with its own Init/Center sends simulated) — the corpus
/// replay contract. Returns the number of frames on a clean parse.
fn replay(bytes: &[u8]) -> Result<usize, elastic_train::error::Error> {
    let mut slice = bytes;
    let mut ck = WireClock::default();
    let mut proto = ProtocolState::master();
    let mut frames = 0usize;
    while !slice.is_empty() {
        let f = recv_frame(&mut slice, &mut ck)?;
        proto.advance(Dir::Recv, f.kind)?;
        frames += 1;
        // Simulate the master's own turn so worker-originated streams
        // can drive the whole table.
        match proto.state() {
            ProtoState::SendInit => proto.advance(Dir::Send, FrameKind::Init)?,
            ProtoState::Reply => proto.advance(Dir::Send, FrameKind::Center)?,
            _ => {}
        }
    }
    Ok(frames)
}

/// Run one mutation class; `Some(description)` on contract violation.
fn mutate_and_check(rng: &mut Rng, base: &Frame, mut buf: Vec<u8>) -> Option<String> {
    let mut ck = WireClock::default();
    match rng.below(8) {
        // Valid bytes decode to the identical frame.
        0 => match recv_frame(&mut buf.as_slice(), &mut ck) {
            Ok(f) if f == *base => None,
            Ok(f) => Some(format!("valid {:?} frame decoded unequal ({:?})", base.kind, f.kind)),
            Err(e) => Some(format!("valid {:?} frame rejected: {e}", base.kind)),
        },
        // Header bit flip: typed result either way, never a panic.
        1 => {
            let bit = rng.below(HEADER_BYTES * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
            let _ = recv_frame(&mut buf.as_slice(), &mut ck);
            None
        }
        // Payload bit flip: payload bytes are arbitrary f32s, so the
        // frame must still decode.
        2 => {
            if buf.len() > HEADER_BYTES {
                let bit = rng.below((buf.len() - HEADER_BYTES) * 8);
                buf[HEADER_BYTES + bit / 8] ^= 1 << (bit % 8);
                if let Err(e) = recv_frame(&mut buf.as_slice(), &mut ck) {
                    return Some(format!("payload bit flip must stay decodable: {e}"));
                }
            }
            None
        }
        // Truncation: always a typed mid-stream error.
        3 => {
            buf.truncate(rng.below(buf.len().max(1)));
            match recv_frame(&mut buf.as_slice(), &mut ck) {
                Err(_) => None,
                Ok(_) => Some("truncated frame decoded cleanly".to_string()),
            }
        }
        // Length-field lie under the cap: shrink ⇒ clean shorter
        // decode; grow ⇒ typed payload-EOF error.
        4 => {
            let lie = rng.below(2 * base.payload.len() + 9) as u32;
            buf[19..23].copy_from_slice(&lie.to_le_bytes());
            match recv_frame(&mut buf.as_slice(), &mut ck) {
                Ok(f) if (lie as usize) <= base.payload.len() => {
                    (f.payload.len() != lie as usize)
                        .then(|| format!("shrunk length {lie} decoded {} f32s", f.payload.len()))
                }
                Ok(_) => Some(format!("length lie {lie} > actual {} decoded", base.payload.len())),
                Err(_) if (lie as usize) > base.payload.len() => None,
                Err(e) => Some(format!("shrunk length {lie} must decode: {e}")),
            }
        }
        // Unknown kind byte: a typed error naming the kind.
        5 => {
            buf[6] = 7 + (rng.below(249) as u8);
            match recv_frame(&mut buf.as_slice(), &mut ck) {
                Err(e) if format!("{e}").contains("kind") => None,
                Err(e) => Some(format!("unknown kind error must name the kind: {e}")),
                Ok(_) => Some("unknown kind decoded cleanly".to_string()),
            }
        }
        // Magic/version stomp: named rejections.
        6 => {
            if rng.below(2) == 0 {
                let bad = (rng.next_u64() as u32) ^ MAGIC ^ 1;
                buf[0..4].copy_from_slice(&(if bad == MAGIC { !MAGIC } else { bad }).to_le_bytes());
                match recv_frame(&mut buf.as_slice(), &mut ck) {
                    Err(e) if format!("{e}").contains("magic") => None,
                    other => Some(format!("magic stomp: {other:?}")),
                }
            } else {
                let bad = (rng.next_u64() as u16) | 0x8000;
                debug_assert_ne!(bad, VERSION);
                buf[4..6].copy_from_slice(&bad.to_le_bytes());
                match recv_frame(&mut buf.as_slice(), &mut ck) {
                    Err(e) if format!("{e}").contains("version") => None,
                    other => Some(format!("version stomp: {other:?}")),
                }
            }
        }
        // Max-n claims — the teeth. Above the cap the error must come
        // FROM the cap (named), not from running out of bytes: a build
        // with the guard compiled out fails exactly here.
        _ => {
            let claim = match rng.below(3) {
                0 => MAX_PAYLOAD,
                1 => MAX_PAYLOAD + 1,
                _ => u32::MAX,
            };
            buf[19..23].copy_from_slice(&claim.to_le_bytes());
            match recv_frame(&mut buf.as_slice(), &mut ck) {
                Ok(_) => Some(format!("max-n claim {claim} decoded cleanly")),
                Err(e) if claim > MAX_PAYLOAD && !format!("{e}").contains("cap") => Some(format!(
                    "claim {claim} exceeds MAX_PAYLOAD {MAX_PAYLOAD} but was not rejected \
                     by the cap guard (got: {e}) — is the guard compiled out?"
                )),
                Err(_) => None,
            }
            .or_else(|| protocol_walk(rng))
        }
    }
}

/// Random walk over one side's state machine: admissible steps follow
/// the table; hostile steps must produce rejections naming the state
/// and the frame, without advancing it.
fn protocol_walk(rng: &mut Rng) -> Option<String> {
    let mut p =
        if rng.below(2) == 0 { ProtocolState::master() } else { ProtocolState::worker() };
    for _ in 0..24 {
        let follow = rng.below(2) == 0 && !p.is_terminal();
        let (dir, kind) = if follow {
            let options: Vec<_> =
                TRANSITIONS.iter().filter(|&&(s, _, _, _)| s == p.state()).collect();
            let &&(_, d, k, _) = &options[rng.below(options.len())];
            (d, k)
        } else {
            let d = if rng.below(2) == 0 { Dir::Send } else { Dir::Recv };
            (d, FrameKind::ALL[rng.below(FrameKind::ALL.len())])
        };
        let before = p.state();
        if let Err(e) = p.advance(dir, kind) {
            let msg = format!("{e}");
            if !msg.contains("protocol violation") || !msg.contains(&format!("{before:?}")) {
                return Some(format!("rejection must name the state: {msg}"));
            }
            if p.state() != before {
                return Some(format!("{:?}: a rejection advanced the state", p.side()));
            }
        }
        if p.is_terminal() {
            // Terminal states reject everything, on both sides.
            for &(d, k) in &[(Dir::Send, FrameKind::Hello), (Dir::Recv, FrameKind::Done)] {
                if p.advance(d, k).is_ok() {
                    return Some(format!("terminal {:?} accepted {k:?}", before));
                }
            }
            break;
        }
    }
    None
}
